"""AST -> normalized Algebricks logical plan (paper §3.3 / §4 intro).

Normalization deliberately over-protects correctness, exactly as the
paper describes, so the rewrite rules have something real to remove:

* every child path step becomes
    ASSIGN( $sorted : sort-distinct-nodes-asc-or-atomics($agg) )
    SUBPLAN { AGGREGATE( $agg : create_sequence(
                  child(treat($it, element_node), "name")) )
              UNNEST( $it : iterate($in) )
              NESTED-TUPLE-SOURCE }
* ``doc``/``collection`` become ASSIGN(doc(promote(data(lit), string)))
* FLWOR ``for`` -> UNNEST(iterate), ``let`` -> ASSIGN,
  ``where`` -> SELECT(boolean(...))
* scalar aggregates over a FLWOR become the §4.2.2 shape:
    ASSIGN( $v : count(treat($seq, any_type)) )
    SUBPLAN { AGGREGATE( $seq : create_sequence($ret) ) <flwor ops> NTS }
* the query result is unnested (UNNEST iterate) into DISTRIBUTE-RESULT.

Deviations (documented, DESIGN.md §4): quantified expressions stay
composite ``Some`` scalars; multi-item ``return (a, b, c)`` keeps tuple
shape in DISTRIBUTE-RESULT instead of flattening.
"""
from __future__ import annotations

import dataclasses

from repro.core import xqparser as xq
from repro.core.algebra import (FUNCTIONS, Aggregate, Assign, Call, Const,
                                DistributeResult, EmptyTupleSource, Expr,
                                GroupBy, Limit, NestedTupleSource, Op,
                                OrderBy, Select, Some, Subplan, Unnest,
                                Var)
from repro.core.errors import QueryError, TranslateError, UnsupportedError

_CMP = {"eq": "value-eq", "ne": "value-ne", "lt": "value-lt",
        "le": "value-le", "gt": "value-gt", "ge": "value-ge"}
_ARITH = {"add": "add", "sub": "subtract", "mul": "multiply",
          "div": "divide"}
_AGG_FNS = ("count", "sum", "min", "max", "avg")


@dataclasses.dataclass
class _Env:
    vars: dict[str, int]
    node_valued: dict[int, bool]


class Translator:
    def __init__(self) -> None:
        self._next = 0

    def new_var(self) -> int:
        self._next += 1
        return self._next

    # -- expression helpers ---------------------------------------------

    def _atomize(self, e: Expr, is_node: bool) -> Expr:
        return Call("data", (e,)) if is_node else e

    def _lookup(self, ast: xq.Ref, env: _Env) -> int:
        v = env.vars.get(ast.name)
        if v is None:
            raise TranslateError(f"unbound variable ${ast.name}",
                                 pos=ast.pos)
        return v

    def _check_fn(self, ast: xq.Fn) -> None:
        if ast.name not in FUNCTIONS:
            raise TranslateError(f"unknown function {ast.name}()",
                                 pos=ast.pos)

    def _is_node_ast(self, ast: xq.Ast, env: _Env) -> bool:
        if isinstance(ast, xq.Path):
            return True
        if isinstance(ast, xq.Ref):
            return env.node_valued.get(self._lookup(ast, env), True)
        if isinstance(ast, xq.Fn):
            return ast.name in ("doc", "collection")
        return False

    # -- pure translation (no plan ops): quantifier bodies ---------------

    def pure_expr(self, ast: xq.Ast, env: _Env) -> Expr:
        if isinstance(ast, xq.Lit):
            return Const(ast.value, ast.typ)
        if isinstance(ast, xq.Ref):
            return Var(self._lookup(ast, env))
        if isinstance(ast, xq.Path):
            e = self.pure_expr(ast.base, env)
            for step in ast.steps:
                e = Call("child", (Call("treat",
                                        (e, Const("element_node", "type"))),
                                   Const(step, "string")))
            return e
        if isinstance(ast, xq.Bin):
            if ast.op in ("and", "or"):
                return Call(ast.op, (self.pure_expr(ast.left, env),
                                     self.pure_expr(ast.right, env)))
            fn = _CMP.get(ast.op) or _ARITH[ast.op]
            le = self._atomize(self.pure_expr(ast.left, env),
                               self._is_node_ast(ast.left, env))
            re_ = self._atomize(self.pure_expr(ast.right, env),
                                self._is_node_ast(ast.right, env))
            return Call(fn, (le, re_))
        if isinstance(ast, xq.Fn):
            self._check_fn(ast)
            args = tuple(self.pure_expr(a, env) for a in ast.args)
            return Call(ast.name, args)
        raise UnsupportedError(
            f"unsupported expression in quantifier body: {ast}",
            pos=getattr(ast, "pos", -1))

    # -- plan-building translation ---------------------------------------

    def path_step(self, plan: Op, invar: int, step: str
                  ) -> tuple[Op, int]:
        """The paper's 3-stage path step (iterate/collect/sort)."""
        it, agg, srt = self.new_var(), self.new_var(), self.new_var()
        nested: Op = NestedTupleSource()
        nested = Unnest(it, Call("iterate", (Var(invar),)), nested)
        step_expr = Call("child",
                         (Call("treat", (Var(it),
                                         Const("element_node", "type"))),
                          Const(step, "string")))
        nested = Aggregate(agg, Call("create_sequence", (step_expr,)),
                           nested)
        plan = Subplan(nested, plan)
        plan = Assign(srt,
                      Call("sort-distinct-nodes-asc-or-atomics",
                           (Var(agg),)), plan)
        return plan, srt

    def expr(self, ast: xq.Ast, env: _Env, plan: Op
             ) -> tuple[Op, Expr, bool]:
        """Returns (plan, expr, is_node_valued)."""
        if isinstance(ast, xq.Lit):
            return plan, Const(ast.value, ast.typ), False
        if isinstance(ast, xq.Ref):
            v = self._lookup(ast, env)
            return plan, Var(v), env.node_valued.get(v, True)
        if isinstance(ast, xq.Path):
            plan, base, _ = self.expr(ast.base, env, plan)
            if not isinstance(base, Var):
                bv = self.new_var()
                plan = Assign(bv, base, plan)
                base = Var(bv)
            v = base.n
            for step in ast.steps:
                plan, v = self.path_step(plan, v, step)
            return plan, Var(v), True
        if isinstance(ast, xq.Fn):
            self._check_fn(ast)
            if ast.name in ("doc", "collection"):
                lit = ast.args[0] if ast.args else None
                if not isinstance(lit, xq.Lit):
                    raise TranslateError(
                        f"{ast.name}() needs a string-literal argument",
                        pos=ast.pos)
                inner = Call("promote", (Call("data",
                                              (Const(lit.value, "string"),)),
                                         Const("string", "type")))
                v = self.new_var()
                plan = Assign(v, Call(ast.name, (inner,)), plan)
                return plan, Var(v), True
            if ast.name in _AGG_FNS:
                return self.aggregate_call(ast, env, plan)
            args = []
            for a in ast.args:
                plan, e, _ = self.expr(a, env, plan)
                args.append(e)
            return plan, Call(ast.name, tuple(args)), False
        if isinstance(ast, xq.Bin):
            if ast.op in ("and", "or"):
                plan, le, _ = self.expr(ast.left, env, plan)
                plan, re_, _ = self.expr(ast.right, env, plan)
                return plan, Call(ast.op, (le, re_)), False
            fn = _CMP.get(ast.op) or _ARITH[ast.op]
            plan, le, ln = self.expr(ast.left, env, plan)
            plan, re_, rn = self.expr(ast.right, env, plan)
            return plan, Call(fn, (self._atomize(le, ln),
                                   self._atomize(re_, rn))), False
        if isinstance(ast, xq.SomeQ):
            plan, src, _ = self.expr(ast.source, env, plan)
            qv = self.new_var()
            inner_env = _Env({**env.vars, ast.var: qv},
                             {**env.node_valued, qv: True})
            cond = self.pure_expr(ast.cond, inner_env)
            return plan, Some(qv, src, cond), False
        if isinstance(ast, xq.Seq):
            raise UnsupportedError(
                "sequence construction is only supported in return "
                "position", pos=ast.pos)
        if isinstance(ast, xq.Flwor):
            # FLWOR in expression position: collect its stream into a
            # sequence (create_sequence SUBPLAN), §4.2.2 shape.
            nested, ret_vars = self.flwor_stream(ast, env,
                                                 NestedTupleSource())
            if len(ret_vars) != 1:
                raise TranslateError(
                    "a FLWOR in expression position must return a "
                    "single item", pos=ast.pos)
            seq = self.new_var()
            nested = Aggregate(seq, Call("create_sequence",
                                         (Var(ret_vars[0]),)), nested)
            plan = Subplan(nested, plan)
            return plan, Var(seq), True
        raise UnsupportedError(f"unsupported expression: {ast}",
                               pos=getattr(ast, "pos", -1))

    def aggregate_call(self, ast: xq.Fn, env: _Env, plan: Op
                       ) -> tuple[Op, Expr, bool]:
        """count/sum/... over FLWOR or path: ASSIGN(scalar agg) over
        SUBPLAN{AGGREGATE(create_sequence)}, per §4.2.2."""
        (arg,) = ast.args
        plan, seq_expr, _ = self.expr(arg, env, plan)
        call = Call(ast.name, (Call("treat", (seq_expr,
                                              Const("any_type", "type"))),))
        return plan, call, False

    def flwor_stream(self, ast: xq.Flwor, env: _Env, plan: Op
                     ) -> tuple[Op, list[int]]:
        """Translate FLWOR clauses onto ``plan`` as a tuple stream;
        returns (plan, return-item vars)."""
        env = _Env(dict(env.vars), dict(env.node_valued))
        for ci, cl in enumerate(ast.clauses):
            if cl[0] == "groupby":
                return self._group_by(cl, ast.clauses[ci + 1:], ast,
                                      env, plan)
            if cl[0] == "for":
                _, name, src = cl
                plan, e, is_node = self.expr(src, env, plan)
                if not isinstance(e, Var):
                    sv = self.new_var()
                    plan = Assign(sv, e, plan)
                    e = Var(sv)
                x = self.new_var()
                plan = Unnest(x, Call("iterate", (e,)), plan)
                env.vars[name] = x
                env.node_valued[x] = is_node
            elif cl[0] == "let":
                _, name, src = cl
                plan, e, is_node = self.expr(src, env, plan)
                x = self.new_var()
                plan = Assign(x, e, plan)
                env.vars[name] = x
                env.node_valued[x] = is_node
            elif cl[0] == "where":
                plan, e, _ = self.expr(cl[1], env, plan)
                plan = Select(Call("boolean", (e,)), plan)
            elif cl[0] in ("orderby", "limit"):
                raise UnsupportedError(
                    "order by / limit are supported after group by "
                    "only (ordered grouped output)",
                    pos=(cl[1].pos if isinstance(cl[1], xq.Ast)
                         else ast.pos))
            else:
                raise TranslateError(
                    f"unsupported FLWOR clause {cl[0]!r}", pos=ast.pos)
        # return clause
        items = (ast.ret.items if isinstance(ast.ret, xq.Seq)
                 else (ast.ret,))
        ret_vars: list[int] = []
        for item in items:
            plan, e, _ = self.expr(item, env, plan)
            if isinstance(e, Var):
                ret_vars.append(e.n)
            else:
                rv = self.new_var()
                plan = Assign(rv, e, plan)
                ret_vars.append(rv)
        return plan, ret_vars

    def _group_by(self, cl, rest: tuple, ast: xq.Flwor, env: _Env,
                  plan: Op) -> tuple[Op, list[int]]:
        """XQuery 3.0-lite group-by (paper §6 future work). Return
        items — and any HAVING-style ``where``, ``order by`` and
        ``limit`` clauses *after* the group-by — are expressions over
        the grouping key and aggregate functions of per-tuple
        expressions. Lowered to the keyed two-step GROUP-BY operator
        (segmented reduce locally, psum globally — rule 4.2.2
        generalized), with post-group SELECTs for the HAVING filters,
        post-group ASSIGNs for non-variable return expressions (e.g.
        ``avg(..) div 10``), ORDER-BY over the grouped stream (keys
        share aggregate slots with HAVING/return; the grouping key is
        appended as a total-order tiebreak) and LIMIT for top-k."""
        _, gname, key_ast = cl
        plan, key_e, _ = self.expr(key_ast, env, plan)
        key_var = self.new_var()
        aggs: list[tuple[int, str, Expr]] = []
        slots: dict[xq.Ast, int] = {}

        def agg_slot(item: xq.Fn) -> int:
            """One GROUP-BY aggregate slot per distinct (fn, arg) call
            — shared between HAVING conditions and return items."""
            nonlocal plan
            if item in slots:
                return slots[item]
            plan, val_e, _ = self.expr(item.args[0], env, plan)
            v = self.new_var()
            aggs.append((v, item.name, val_e))
            slots[item] = v
            return v

        def post(a: xq.Ast) -> Expr:
            """Post-group expression: aggregate calls and the grouping
            key become GROUP-BY output variables; scalar structure on
            top stays expression-level."""
            if isinstance(a, xq.Ref) and a.name == gname:
                return Var(key_var)
            if isinstance(a, xq.Fn) and a.name in _AGG_FNS:
                return Var(agg_slot(a))
            if isinstance(a, xq.Lit):
                return Const(a.value, a.typ)
            if isinstance(a, xq.Bin):
                if a.op in ("and", "or"):
                    return Call(a.op, (post(a.left), post(a.right)))
                fn = _CMP.get(a.op) or _ARITH[a.op]
                return Call(fn, (post(a.left), post(a.right)))
            if isinstance(a, xq.Fn):
                self._check_fn(a)
                return Call(a.name, tuple(post(x) for x in a.args))
            raise UnsupportedError(
                "post-group expressions must be built from the "
                f"grouping key and aggregates, got {a}",
                pos=getattr(a, "pos", -1))

        havings: list[Expr] = []
        order_keys: list[tuple[Expr, bool]] = []
        limit_k: int | None = None
        for rc in rest:
            rc_pos = (rc[1].pos if len(rc) > 1 and isinstance(rc[1], xq.Ast)
                      else ast.pos)
            if rc[0] == "where":
                if order_keys or limit_k is not None:
                    raise UnsupportedError(
                        "HAVING where must precede order by / limit",
                        pos=rc_pos)
                havings.append(post(rc[1]))
            elif rc[0] == "orderby":
                order_keys.append((post(rc[1]), rc[2]))
            elif rc[0] == "limit":
                if not order_keys:
                    raise UnsupportedError(
                        "limit without order by has no deterministic "
                        "row selection; add an order by clause",
                        pos=rc_pos)
                if limit_k is not None:
                    raise UnsupportedError("duplicate limit clause",
                                           pos=rc_pos)
                if rc[1] < 1:
                    raise TranslateError(
                        f"limit must be >= 1, got {rc[1]}", pos=rc_pos)
                limit_k = rc[1]
            else:
                raise UnsupportedError(
                    f"only where (HAVING) / order by / limit may "
                    f"follow group by, got {rc[0]}", pos=rc_pos)
        items = (ast.ret.items if isinstance(ast.ret, xq.Seq)
                 else (ast.ret,))
        ret_vars: list[int] = []
        deferred: list[tuple[int, Expr]] = []
        for item in items:
            e = post(item)
            if isinstance(e, Var):
                ret_vars.append(e.n)
            else:
                rv = self.new_var()
                deferred.append((rv, e))
                ret_vars.append(rv)
        plan = GroupBy(key_var, key_e, tuple(aggs), plan)
        for hv in havings:
            plan = Select(Call("boolean", (hv,)), plan)
        for rv, e in deferred:
            plan = Assign(rv, e, plan)
        if order_keys:
            # the grouping key (unique per output tuple) as a final
            # ascending tiebreak makes the ordering total, so device
            # sort, host oracles and batch layouts all agree exactly
            order_keys.append((Var(key_var), False))
            plan = OrderBy(tuple(order_keys), plan)
        if limit_k is not None:
            plan = Limit(limit_k, plan)
        return plan, ret_vars

    # -- entry point -------------------------------------------------------

    def translate(self, ast: xq.Ast) -> Op:
        env = _Env({}, {})
        plan: Op = EmptyTupleSource()
        if isinstance(ast, xq.Flwor):
            plan, ret_vars = self.flwor_stream(ast, env, plan)
            if len(ret_vars) == 1:
                out = self.new_var()
                plan = Unnest(out, Call("iterate", (Var(ret_vars[0]),)),
                              plan)
                return DistributeResult((out,), plan)
            return DistributeResult(tuple(ret_vars), plan)
        plan, e, _ = self.expr(ast, env, plan)
        if not isinstance(e, Var):
            v = self.new_var()
            plan = Assign(v, e, plan)
            e = Var(v)
        out = self.new_var()
        plan = Unnest(out, Call("iterate", (e,)), plan)
        return DistributeResult((out,), plan)


def translate(query: str) -> Op:
    try:
        return Translator().translate(xq.parse(query))
    except QueryError as e:
        raise e.with_text(query)
