"""Fixpoint rewrite driver + plan analyses (paper §4 intro, §4.1.1).

The driver mirrors Algebricks' staged rule sets: each stage is a list
of rules applied bottom-up to a fixpoint. ``Context`` carries the
whole-plan analyses the rules key on:

* ``use``        variable use counts (inline / dead-code decisions)
* ``singleton``  vars guaranteed to hold exactly one item per tuple
* ``props``      (document-ordered, duplicate-free) lattice per var —
                 the property tracking of rule 4.1.1 (after [19])
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Optional

from repro.core.algebra import (Aggregate, Assign, Call, Const, DataScan,
                                Expr, Op, Some, Subplan, Unnest, Var,
                                fn_info, free_vars, transform_bottom_up,
                                var_use_counts, walk)
from repro.core.obs import trace as obs_trace

Rule = Callable[[Op, "Context"], Optional[Op]]


@dataclasses.dataclass
class Context:
    use: dict[int, int]
    singleton: dict[int, bool]
    props: dict[int, tuple[bool, bool]]   # (ordered, nodup)
    max_var: int = 0

    def fresh(self) -> int:
        """Globally fresh variable (rules must not mint locally —
        nested plans don't see outer defs)."""
        self.max_var += 1
        return self.max_var

    @classmethod
    def analyze(cls, root: Op) -> "Context":
        from repro.core.algebra import (DistributeResult, defined_vars,
                                        used_exprs)
        use = var_use_counts(root)
        max_var = 0
        for op in walk(root):
            # defined_vars (not defined_var): GROUP-BY defines its key
            # and aggregate vars, and DISTRIBUTE-RESULT's vars may be
            # exactly those — missing either would let fresh() mint a
            # colliding variable
            max_var = max(max_var, *defined_vars(op), 0)
            if isinstance(op, DistributeResult):
                max_var = max(max_var, *op.vars, 0)
            for e in used_exprs(op):
                max_var = max(max_var, max(free_vars(e), default=0))
        singleton: dict[int, bool] = {}
        props: dict[int, tuple[bool, bool]] = {}
        # resolve def-chains to fixpoint (defs may reference later-
        # visited vars across subplan boundaries; a few passes suffice)
        defs = [op for op in walk(root)
                if isinstance(op, (Assign, Unnest, Aggregate, DataScan))]
        for _ in range(len(defs) + 1):
            changed = False
            for op in defs:
                if isinstance(op, DataScan):
                    s, p = True, (True, True)
                elif isinstance(op, Unnest):
                    s = True          # unnest emits one item per tuple
                    p = expr_props(op.expr, props)
                elif isinstance(op, Aggregate):
                    s = True          # aggregates produce one value
                    p = expr_props(op.expr, props)
                else:
                    s = expr_singleton(op.expr, singleton)
                    p = expr_props(op.expr, props)
                if singleton.get(op.var) != s or props.get(op.var) != p:
                    singleton[op.var] = s
                    props[op.var] = p
                    changed = True
            if not changed:
                break
        return cls(use=use, singleton=singleton, props=props,
                   max_var=max_var)


def expr_singleton(e: Expr, flags: dict[int, bool]) -> bool:
    if isinstance(e, Const):
        return True
    if isinstance(e, Var):
        return flags.get(e.n, False)
    if isinstance(e, Some):
        return True
    if isinstance(e, Call):
        info = fn_info(e.fn)
        if info.card == "one":
            return True
        if info.card == "same":
            return all(expr_singleton(a, flags) for a in e.args)
        return False
    return False


def expr_props(e: Expr, props: dict[int, tuple[bool, bool]]
               ) -> tuple[bool, bool]:
    """(document-ordered, duplicate-free) of an expression's value."""
    if isinstance(e, (Const, Some)):
        return (True, True)
    if isinstance(e, Var):
        return props.get(e.n, (False, False))
    if isinstance(e, Call):
        info = fn_info(e.fn)
        if e.fn in ("doc", "collection"):
            return (True, True)
        if e.fn == "sort-distinct-nodes-asc-or-atomics":
            return (True, True)
        if e.fn == "sort-nodes-asc-or-atomics":
            return (True, expr_props(e.args[0], props)[1])
        if e.fn == "distinct-nodes-or-atomics":
            return (expr_props(e.args[0], props)[0], True)
        args = [expr_props(a, props) for a in e.args] or [(True, True)]
        o = all(a[0] for a in args) and info.preserves_order
        d = all(a[1] for a in args) and info.preserves_nodup
        return (o, d)
    return (False, False)


def remove_identity_assigns(root: Op) -> Op:
    """Drop ASSIGN($v: $u) ops, substituting $u for $v globally.

    Identity assigns appear after sort-distinct removal (4.1.1 replaces
    the expression with its argument) and would otherwise block the
    operator-adjacency patterns of 4.1.2/4.1.3.
    """
    from repro.core.algebra import (DistributeResult, substitute,
                                    used_exprs, with_children, children)
    mapping: dict[int, Var] = {}
    for op in walk(root):
        if isinstance(op, Assign) and isinstance(op.expr, Var):
            mapping[op.var] = op.expr
    if not mapping:
        return root
    # resolve transitive chains
    def resolve(v: int) -> Var:
        seen = set()
        while v in mapping and v not in seen:
            seen.add(v)
            v = mapping[v].n
        return Var(v)
    mapping = {k: resolve(k) for k in mapping}

    def f(op: Op) -> Op:
        if isinstance(op, Assign) and isinstance(op.expr, Var):
            return op.child
        if isinstance(op, (Assign, Unnest, Aggregate)):
            return op.replace(expr=substitute(op.expr, mapping))
        if isinstance(op, DataScan):
            return op
        from repro.core.algebra import GroupBy, Join, OrderBy, Select
        if isinstance(op, Select):
            return op.replace(expr=substitute(op.expr, mapping))
        if isinstance(op, OrderBy):
            return op.replace(keys=tuple(
                (substitute(e, mapping), d) for e, d in op.keys))
        if isinstance(op, GroupBy):
            return op.replace(
                key_expr=substitute(op.key_expr, mapping),
                aggs=tuple((v, fn, substitute(e, mapping))
                           for v, fn, e in op.aggs))
        if isinstance(op, Join):
            return op.replace(
                cond=substitute(op.cond, mapping),
                hash_keys=tuple((substitute(a, mapping),
                                 substitute(b, mapping))
                                for a, b in op.hash_keys))
        if isinstance(op, DistributeResult):
            return op.replace(vars=tuple(
                mapping[v].n if v in mapping else v for v in op.vars))
        return op

    return transform_bottom_up(root, f)


def apply_rule_once(root: Op, rule: Rule) -> tuple[Op, bool]:
    """Apply ``rule`` at the first (bottom-up) matching node only."""
    ctx = Context.analyze(root)
    fired = [False]

    def f(op: Op) -> Op:
        if fired[0]:
            return op
        new = rule(op, ctx)
        if new is not None:
            fired[0] = True
            return new
        return op

    return transform_bottom_up(root, f), fired[0]


# -- rewrite soundness (debug/CI mode) ---------------------------------------

_CHECK_REWRITES = os.environ.get("REPRO_CHECK_REWRITES", "") not in ("",
                                                                    "0")


def set_soundness_checks(on: bool) -> bool:
    """Toggle per-firing soundness checks (analysis/check.py): after
    every rule application the plan's result schema must be equivalent
    and its capacity set monotone.  Debug/CI mode — the default-off
    path adds zero work.  Returns the previous setting.  Also
    switchable via the ``REPRO_CHECK_REWRITES=1`` environment
    variable."""
    global _CHECK_REWRITES
    prev = _CHECK_REWRITES
    _CHECK_REWRITES = bool(on)
    return prev


def soundness_checks_enabled() -> bool:
    return _CHECK_REWRITES


def run_rules(root: Op, rules: list[Rule], max_iters: int = 200) -> Op:
    """Apply a rule stage to fixpoint (one rule firing per pass so
    analyses stay fresh — plans here are small, clarity wins)."""
    root = remove_identity_assigns(root)
    for _ in range(max_iters):
        for rule in rules:
            prev = root
            root, fired = apply_rule_once(root, rule)
            if fired:
                root = remove_identity_assigns(root)
                # one instant per rule firing through the ambient
                # tracer (a no-op unless the service installed one
                # around prepare — obs/trace.using)
                obs_trace.current().event(
                    "rewrite-rule", cat="rewrite",
                    rule=getattr(rule, "__name__", str(rule)))
                if _CHECK_REWRITES:
                    from repro.core.analysis.check import check_rewrite
                    check_rewrite(prev, root,
                                  getattr(rule, "__name__", str(rule)))
                break
        else:
            return root
    return root


def optimize(root: Op, trace: Optional[list] = None) -> Op:
    """The full staged pipeline: path rules -> parallel rules ->
    cleanup (mirrors Logical-to-Logical staging in §3.2)."""
    from repro.core.rewrite import parallel_rules, path_rules

    stages = [
        ("path", path_rules.RULES),
        ("parallel", parallel_rules.RULES),
        ("cleanup", path_rules.CLEANUP_RULES),
    ]
    for name, rules in stages:
        with obs_trace.current().span(f"rewrite.{name}",
                                      cat="rewrite"):
            root = run_rules(root, rules)
        if trace is not None:
            trace.append((name, root))
    return root
