"""Path expression rewrite rules (paper §4.1) + generic cleanups.

Rule names map 1:1 onto the paper's subsections:
  4.1.1 remove_sort_distinct
  4.1.2 remove_subplan_iterate
  4.1.3 scalar_to_unnest
  4.1.4 combine_unnest
plus the Algebricks-generic rules the paper leans on implicitly:
  inline_singleton_subplan  (collapse inner focus for singleton input —
                             what turns where-clause steps into plain
                             ASSIGN(child(...)), visible in §4.2.3's
                             ASSIGN($$28:data(child($$26,"title"))))
  inline_single_use_assign / inline_var_assign / remove_dead_assign
"""
from __future__ import annotations

from typing import Optional

from repro.core.algebra import (Aggregate, Assign, Call, Expr,
                                NestedTupleSource, Op, Select, Some,
                                Subplan, Unnest, Var, fn_info, free_vars,
                                substitute, transform_bottom_up)
from repro.core.rewrite.engine import Context, expr_props


# --- 4.1.1 ------------------------------------------------------------------

def remove_sort_distinct(op: Op, ctx: Context) -> Optional[Op]:
    """ASSIGN($v: sort-distinct(...)($u)) -> weaker/no-op form when the
    tracked (order, nodup) properties are already intact."""
    if not (isinstance(op, Assign) and isinstance(op.expr, Call)):
        return None
    fn = op.expr.fn
    if fn not in ("sort-distinct-nodes-asc-or-atomics",
                  "sort-nodes-asc-or-atomics",
                  "distinct-nodes-or-atomics"):
        return None
    arg = op.expr.args[0]
    ordered, nodup = expr_props(arg, ctx.props)
    need_sort = "sort" in fn and not ordered
    need_distinct = "distinct" in fn and not nodup
    if need_sort and need_distinct:
        return None
    if need_sort:
        new = Call("sort-nodes-asc-or-atomics", (arg,))
    elif need_distinct:
        new = Call("distinct-nodes-or-atomics", (arg,))
    else:
        new = arg   # both properties intact: drop the expression
    if new == op.expr:
        return None
    return op.replace(expr=new)


# --- 4.1.2 ------------------------------------------------------------------

def _splice_nested(nested: Op, onto: Op) -> Op:
    """Replace the NESTED-TUPLE-SOURCE leaf of ``nested`` with ``onto``
    (merging @NESTED into the outer plan)."""

    def f(o: Op) -> Op:
        return onto if isinstance(o, NestedTupleSource) else o

    return transform_bottom_up(nested, f)


def remove_subplan_iterate(op: Op, ctx: Context) -> Optional[Op]:
    """UNNEST($r: iterate($s)) over SUBPLAN{AGGREGATE($s:
    create_sequence(@exp0)) @NESTED NTS} ->
    UNNEST($r: iterate($t)) over ASSIGN($t: @exp0) over @NESTED."""
    if not (isinstance(op, Unnest) and isinstance(op.expr, Call)
            and op.expr.fn == "iterate"
            and isinstance(op.expr.args[0], Var)
            and isinstance(op.child, Subplan)):
        return None
    s = op.expr.args[0].n
    sp = op.child
    agg = sp.plan
    if not (isinstance(agg, Aggregate) and agg.var == s
            and isinstance(agg.expr, Call)
            and agg.expr.fn == "create_sequence"
            and ctx.use.get(s, 0) == 1):
        return None
    exp0 = agg.expr.args[0]
    tmp = ctx.fresh()
    merged = _splice_nested(agg.child, sp.child)
    return Unnest(op.var, Call("iterate", (Var(tmp),)),
                  Assign(tmp, exp0, merged))


# --- generic: collapse inner focus when the input is a singleton ------------

def inline_singleton_subplan(op: Op, ctx: Context) -> Optional[Op]:
    """SUBPLAN{AGGREGATE($s: create_sequence(e0)) UNNEST($it:
    iterate($v)) NTS} with singleton $v  ->  ASSIGN($s: e0[$it := $v]).

    The inner focus iterates a single item; the aggregate re-wraps it.
    Both are identities, leaving a scalar assign (cf. the plain
    ASSIGN(child(...)) ops in the paper's §4.2.3 plans)."""
    if not isinstance(op, Subplan):
        return None
    agg = op.plan
    if not (isinstance(agg, Aggregate) and isinstance(agg.expr, Call)
            and agg.expr.fn == "create_sequence"):
        return None
    un = agg.child
    if not (isinstance(un, Unnest) and isinstance(un.expr, Call)
            and un.expr.fn == "iterate"
            and isinstance(un.expr.args[0], Var)
            and isinstance(un.child, NestedTupleSource)):
        return None
    v = un.expr.args[0].n
    if not ctx.singleton.get(v, False):
        return None
    e0 = substitute(agg.expr.args[0], {un.var: Var(v)})
    return Assign(agg.var, e0, op.child)


# --- 4.1.3 ------------------------------------------------------------------

def scalar_to_unnest(op: Op, ctx: Context) -> Optional[Op]:
    """UNNEST($r: iterate($sv)) over ASSIGN($sv: scalar-with-unnest-form)
    -> UNNEST($r: unnest_form(...)) when $sv is used once."""
    if not (isinstance(op, Unnest) and isinstance(op.expr, Call)
            and op.expr.fn == "iterate"
            and isinstance(op.expr.args[0], Var)
            and isinstance(op.child, Assign)):
        return None
    sv = op.expr.args[0].n
    a = op.child
    if a.var != sv or ctx.use.get(sv, 0) != 1:
        return None
    if not (isinstance(a.expr, Call)
            and fn_info(a.expr.fn).unnest_form is not None):
        return None
    return Unnest(op.var, a.expr, a.child)


# --- 4.1.4 ------------------------------------------------------------------

def _is_unnest_child_form(e: Expr) -> bool:
    return isinstance(e, Call) and e.fn == "child"


def combine_unnest(op: Op, ctx: Context) -> Optional[Op]:
    """UNNEST($r: child(..$u..)) over UNNEST($u: child(...)) -> merge
    the two path steps into one UNNEST (input var substituted)."""
    if not (isinstance(op, Unnest) and _is_unnest_child_form(op.expr)
            and isinstance(op.child, Unnest)
            and _is_unnest_child_form(op.child.expr)):
        return None
    u = op.child.var
    if ctx.use.get(u, 0) != 1 or u not in free_vars(op.expr):
        return None
    merged = substitute(op.expr, {u: op.child.expr})
    return Unnest(op.var, merged, op.child.child)


# --- generic cleanups --------------------------------------------------------

def inline_single_use_assign(op: Op, ctx: Context) -> Optional[Op]:
    """Merge ASSIGN($v: e) into its single consumer directly above
    (Algebricks InlineVariables), for pure scalar e."""
    if not isinstance(op, (Assign, Select, Aggregate)):
        return None
    child = getattr(op, "child", None)
    if not isinstance(child, Assign):
        return None
    v = child.var
    if ctx.use.get(v, 0) != 1:
        return None
    expr = op.expr
    if v not in free_vars(expr):
        return None
    if isinstance(child.expr, Some):
        return None
    # don't fold unnesting sources into scalar positions other than
    # plain variable refs; conservative: inline only scalar calls,
    # vars and consts
    new_expr = substitute(expr, {v: child.expr})
    return op.replace(expr=new_expr, child=child.child)


def remove_dead_assign(op: Op, ctx: Context) -> Optional[Op]:
    if isinstance(op, Assign) and ctx.use.get(op.var, 0) == 0:
        return op.child
    if isinstance(op, Subplan):
        agg = op.plan
        if isinstance(agg, Aggregate) and ctx.use.get(agg.var, 0) == 0:
            return op.child
    return None


# Order mirrors the paper's §4.1 cascade: sort removal enables subplan
# removal, which enables unnest conversion, which enables merging; the
# singleton collapse (paper-implicit) runs last so 4.1.2 gets first go.
RULES = [
    remove_sort_distinct,
    remove_subplan_iterate,
    scalar_to_unnest,
    combine_unnest,
    inline_singleton_subplan,
    remove_dead_assign,
]

CLEANUP_RULES = [
    inline_single_use_assign,
    remove_dead_assign,
]
