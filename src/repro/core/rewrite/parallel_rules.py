"""Parallel rewrite rules (paper §4.2).

  4.2.1 introduce_datascan + push_path_into_datascan
  4.2.2 scalar_agg_to_aggregate + annotate_two_step
  4.2.3 introduce_join (cross product from independent DATASCANs),
        push_into_join (operator pushdown + SELECT/JOIN merge with the
        EBV(value-eq) -> algebricks-eq conversion and hash annotation)
plus split_select_conjunctions (Algebricks-generic, feeds 4.2.3).
"""
from __future__ import annotations

from typing import Optional

from repro.core.algebra import (Aggregate, Assign, Call, Const, DataScan,
                                EmptyTupleSource, Expr, Join, Op, Select,
                                Some, Subplan, Unnest, Var, defined_var,
                                defined_vars, fn_info, free_vars,
                                substitute, walk)
from repro.core.rewrite.engine import Context

TRUE = Const("true", "boolean")


# --- helpers -----------------------------------------------------------------

def _collection_literal(e: Expr) -> Optional[str]:
    """collection(promote(data("/x"), string)) -> "/x"."""
    if not (isinstance(e, Call) and e.fn == "collection"):
        return None
    a = e.args[0]
    while isinstance(a, Call) and a.fn in ("promote", "data"):
        a = a.args[0]
    if isinstance(a, Const):
        return str(a.value)
    return None


def _child_chain(e: Expr) -> Optional[tuple[int, list[str]]]:
    """child(treat(child(treat($v,..),"a"),..),"b") -> ($v, [a, b])."""
    if isinstance(e, Var):
        return e.n, []
    if isinstance(e, Call) and e.fn == "child":
        inner, name = e.args
        if isinstance(inner, Call) and inner.fn == "treat":
            inner = inner.args[0]
        if not isinstance(name, Const):
            return None
        got = _child_chain(inner)
        if got is None:
            return None
        v, names = got
        return v, names + [str(name.value)]
    return None


def _defined_vars(op: Op) -> set[int]:
    out = set()
    for o in walk(op):
        out.update(defined_vars(o))
    return out


# --- 4.2.1 --------------------------------------------------------------------

def introduce_datascan(op: Op, ctx: Context) -> Optional[Op]:
    """UNNEST($r: iterate($c)) over ASSIGN($c: collection(...)) ->
    DATASCAN(collection, $r)."""
    if not (isinstance(op, Unnest) and isinstance(op.expr, Call)
            and op.expr.fn == "iterate"
            and isinstance(op.expr.args[0], Var)
            and isinstance(op.child, Assign)):
        return None
    c = op.expr.args[0].n
    a = op.child
    if a.var != c or ctx.use.get(c, 0) != 1:
        return None
    coll = _collection_literal(a.expr)
    if coll is None:
        return None
    return DataScan(coll, op.var, (), a.child)


def push_path_into_datascan(op: Op, ctx: Context) -> Optional[Op]:
    """UNNEST($r: child-chain($d)) over DATASCAN(...,$d,...) ->
    DATASCAN with the path appended (smaller tuples, §4.2.1)."""
    if not (isinstance(op, Unnest) and isinstance(op.child, DataScan)):
        return None
    got = _child_chain(op.expr)
    if got is None:
        return None
    v, names = got
    ds = op.child
    if v != ds.var or not names or ctx.use.get(v, 0) != 1:
        return None
    return ds.replace(var=op.var, path=ds.path + tuple(names))


# --- 4.2.2 --------------------------------------------------------------------

_AGG_FNS = ("count", "sum", "min", "max", "avg")


def _find_agg_subcall(e: Expr, seqvar: int) -> Optional[Call]:
    """Find fn(treat($seqvar, any_type)) anywhere inside e."""
    if isinstance(e, Call):
        if e.fn in _AGG_FNS and len(e.args) == 1:
            a = e.args[0]
            if (isinstance(a, Call) and a.fn == "treat"
                    and isinstance(a.args[0], Var)
                    and a.args[0].n == seqvar):
                return e
        for a in e.args:
            r = _find_agg_subcall(a, seqvar)
            if r is not None:
                return r
    return None


def _replace_subexpr(e: Expr, old: Expr, new: Expr) -> Expr:
    if e == old:
        return new
    if isinstance(e, Call):
        return Call(e.fn, tuple(_replace_subexpr(a, old, new)
                                for a in e.args))
    return e


def scalar_agg_to_aggregate(op: Op, ctx: Context) -> Optional[Op]:
    """ASSIGN($v: ..count(treat($s, any_type))..) over SUBPLAN{
    AGGREGATE($s: create_sequence(e0)) ...} -> move the aggregate into
    the AGGREGATE operator (incremental aggregation, §4.2.2)."""
    if not (isinstance(op, Assign) and isinstance(op.child, Subplan)):
        return None
    sp = op.child
    agg = sp.plan
    if not (isinstance(agg, Aggregate) and isinstance(agg.expr, Call)
            and agg.expr.fn == "create_sequence"):
        return None
    s = agg.var
    if ctx.use.get(s, 0) != 1:
        return None
    call = _find_agg_subcall(op.expr, s)
    if call is None:
        return None
    e0 = agg.expr.args[0]
    new_agg_expr = Call(call.fn,
                        (Call("treat", (e0, Const("any_type", "type"))),))
    if op.expr == call:
        # the assign IS the aggregate: retarget the AGGREGATE var
        new_nested = agg.replace(var=op.var, expr=new_agg_expr)
        return sp.replace(plan=new_nested)
    # aggregate appears inside a wider expression (e.g. sum(..) div 10):
    # keep an ASSIGN for the wrapper, aggregate into a fresh var
    w = ctx.fresh()
    new_nested = agg.replace(var=w, expr=new_agg_expr)
    wrapper = _replace_subexpr(op.expr, call, Var(w))
    return Assign(op.var, wrapper, sp.replace(plan=new_nested))


def annotate_two_step(op: Op, ctx: Context) -> Optional[Op]:
    """Annotate AGGREGATE ops over partitioned scans with the
    local/global split (enables partitioned two-step aggregation)."""
    if not (isinstance(op, Aggregate) and op.local_fn is None
            and isinstance(op.expr, Call)):
        return None
    info = fn_info(op.expr.fn)
    if info.two_step is None:
        return None
    if not any(isinstance(o, DataScan) and o.partitioned
               for o in walk(op.child)):
        return None
    loc, glob = info.two_step
    return op.replace(local_fn=loc, global_fn=glob)


# --- generic: conjunct splitting ------------------------------------------------

def split_select_conjunctions(op: Op, ctx: Context) -> Optional[Op]:
    """SELECT(boolean(and(a, b))) -> SELECT(boolean(a)) over
    SELECT(boolean(b)) (enables per-side pushdown)."""
    if not isinstance(op, Select):
        return None
    e = op.expr
    ebv = isinstance(e, Call) and e.fn == "boolean"
    inner = e.args[0] if ebv else e
    if not (isinstance(inner, Call) and inner.fn == "and"):
        return None
    a, b = inner.args
    wrap = (lambda x: Call("boolean", (x,))) if ebv else (lambda x: x)
    return Select(wrap(a), Select(wrap(b), op.child))


# --- 4.2.3 --------------------------------------------------------------------

def introduce_join(op: Op, ctx: Context) -> Optional[Op]:
    """A DATASCAN whose input subtree already contains a DATASCAN is a
    dependent nested loop over independent sources -> cross-product
    JOIN (condition true); predicates merge later."""
    if not isinstance(op, DataScan):
        return None
    if isinstance(op.child, EmptyTupleSource):
        return None
    has_source_below = any(isinstance(o, (DataScan, Join))
                           for o in walk(op.child))
    if not has_source_below:
        return None
    return Join(TRUE, op.child, op.replace(child=EmptyTupleSource()))


def _cross_eq_key(e: Expr, lvars: set[int], rvars: set[int]
                  ) -> Optional[tuple[Expr, Expr]]:
    if not (isinstance(e, Call) and e.fn == "value-eq"):
        return None
    a, b = e.args
    av, bv = free_vars(a), free_vars(b)
    if av and bv:
        if av <= lvars and bv <= rvars:
            return (a, b)
        if av <= rvars and bv <= lvars:
            return (b, a)
    return None


def push_into_join(op: Op, ctx: Context) -> Optional[Op]:
    """Push SELECT/ASSIGN just above a JOIN into the proper branch, or
    merge an equi-SELECT into the JOIN condition (converting the XQuery
    EBV boolean(value-eq(..)) into Algebricks' equal so the physical
    optimizer can pick the hybrid hash join, §4.2.3)."""
    if isinstance(op, (Select, Assign)) and isinstance(op.child, Join):
        j = op.child
        lvars, rvars = _defined_vars(j.left), _defined_vars(j.right)
        e = op.expr
        used = free_vars(e)
        if isinstance(op, Assign):
            if used <= lvars:
                return j.replace(left=op.replace(child=j.left))
            if used <= rvars:
                return j.replace(right=op.replace(child=j.right))
            return None
        # SELECT
        inner = e.args[0] if (isinstance(e, Call) and e.fn == "boolean") \
            else e
        if used <= lvars:
            return j.replace(left=Select(e, j.left))
        if used <= rvars:
            return j.replace(right=Select(e, j.right))
        key = _cross_eq_key(inner, lvars, rvars)
        if key is not None:
            eq = Call("algebricks-eq", key)
            cond = eq if j.cond == TRUE else Call("and", (j.cond, eq))
            return j.replace(cond=cond, hash_keys=j.hash_keys + (key,))
        return None
    return None


RULES = [
    introduce_datascan,
    push_path_into_datascan,
    scalar_agg_to_aggregate,
    split_select_conjunctions,
    introduce_join,
    push_into_join,
    annotate_two_step,
]
