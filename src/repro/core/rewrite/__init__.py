from repro.core.rewrite.engine import (Context, apply_rule_once, optimize,
                                       run_rules)  # noqa: F401
from repro.core.rewrite import path_rules, parallel_rules  # noqa: F401
