#!/usr/bin/env bash
# Tier-1 CI loop: the ROADMAP verify command plus timing report, then
# the serving-benchmark smoke gate (4 variants, 1 repeat — fails fast
# if prepared-query parameter sharing regresses to per-variant
# compiles or results drift from the exact path; the full 64-variant
# run lives in `python -m benchmarks.serving_benchmarks` / the
# slow-marked test).
#
#   scripts/ci.sh              default loop (slow-marked smokes skipped)
#   FULL=1 scripts/ci.sh       include slow-marked arch smoke tests
#   scripts/ci.sh tests/...    any extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
MARK=()
if [ "${FULL:-0}" = "1" ]; then
    MARK=(-m "slow or not slow")
fi
# ${MARK[@]+...} keeps set -u happy on bash < 4.4 when MARK is empty
python -m pytest -x -q --durations=10 \
    ${MARK[@]+"${MARK[@]}"} "$@"
python -m benchmarks.serving_benchmarks --smoke
