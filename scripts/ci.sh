#!/usr/bin/env bash
# Tier-1 CI loop: the ROADMAP verify command plus timing report, then
# the serving-benchmark smoke gates — scan/join, group-by, ordered
# top-k AND async multi-tenant workloads (4 variants, 1 repeat each —
# fails fast if
# prepared-query parameter sharing regresses to per-variant compiles
# or results drift from the exact path; the full 64-variant runs live
# in `python -m benchmarks.serving_benchmarks` / the slow-marked
# tests).
#
#   scripts/ci.sh                 default loop (slow-marked smokes skipped)
#   FULL=1 scripts/ci.sh          include slow-marked arch smoke tests
#   scripts/ci.sh --differential  also run the differential-harness fast
#                                 slice as its own stage (prepared/batch/
#                                 regrowth/scheduled bit-parity across
#                                 queries.ALL)
#   scripts/ci.sh --scheduler     also run the serving-runtime smoke
#                                 stage standalone (admission/fairness/
#                                 bucketing unit+property tests plus the
#                                 4-variant multitenant benchmark gate)
#   scripts/ci.sh --properties    also run the seeded property suites
#                                 (segmented top-k vs host oracle,
#                                 windowed-merge invariance, regrowth
#                                 ladder monotonicity) as their own
#                                 stage — the fast slices; full grids
#                                 are slow-marked (FULL=1)
#   scripts/ci.sh --obs           also run the observability smoke
#                                 stage standalone (tracer/metrics/
#                                 profile unit+property tests plus the
#                                 zero-cost-when-off benchmark gate
#                                 and trace_event export validation)
#   scripts/ci.sh --capacity      also run the capacity-observatory
#                                 smoke stage standalone (flight
#                                 recorder / cost model / deviceless
#                                 simulator tests plus the tiny-trace
#                                 3-load-point sweep with its fidelity
#                                 and round-trip gates)
#   scripts/ci.sh --kernels       also run the kernel stage standalone:
#                                 the segment-engine parity suite under
#                                 REPRO_KERNEL_INTERPRET=1 (the Pallas
#                                 interpreter executes the exact TPU
#                                 kernel bodies on CPU) plus the vmapped
#                                 kernel-vs-jnp policy sweep smoke
#   scripts/ci.sh --persist       also run the persistent-plan-cache
#                                 stage standalone (disk cache round
#                                 trip, restart parity, corruption and
#                                 fingerprint degradation, warmup API,
#                                 eviction counters — plus the 4-variant
#                                 cold-restart benchmark gate; the
#                                 restart suite also rides the default
#                                 loop's `--suite all` smoke pass)
#   scripts/ci.sh --lint          run ONLY the static stage: the
#                                 tracing-hazard/determinism linter
#                                 (file:line findings, nonzero exit)
#                                 plus the whole-suite plan verifier
#                                 (rewrite soundness on, presizing
#                                 cross-validated) — no test run
#   scripts/ci.sh tests/...       any extra pytest args pass through
#
# The default loop runs the linter first (seconds, catches tracing
# hazards before any compile) and the plan verifier after the tests.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
DIFFERENTIAL=0
SCHEDULER=0
PROPERTIES=0
OBS=0
KERNELS=0
CAPACITY=0
PERSIST=0
while [ "${1:-}" = "--differential" ] || [ "${1:-}" = "--scheduler" ] \
        || [ "${1:-}" = "--properties" ] || [ "${1:-}" = "--obs" ] \
        || [ "${1:-}" = "--kernels" ] || [ "${1:-}" = "--capacity" ] \
        || [ "${1:-}" = "--persist" ] || [ "${1:-}" = "--lint" ]; do
    if [ "$1" = "--differential" ]; then DIFFERENTIAL=1; fi
    if [ "$1" = "--scheduler" ]; then SCHEDULER=1; fi
    if [ "$1" = "--properties" ]; then PROPERTIES=1; fi
    if [ "$1" = "--obs" ]; then OBS=1; fi
    if [ "$1" = "--kernels" ]; then KERNELS=1; fi
    if [ "$1" = "--capacity" ]; then CAPACITY=1; fi
    if [ "$1" = "--persist" ]; then PERSIST=1; fi
    if [ "$1" = "--lint" ]; then
        python -m repro.core.analysis.lint src/repro
        python -m repro.core.analysis.verify
        exit 0
    fi
    shift
done
MARK=()
if [ "${FULL:-0}" = "1" ]; then
    MARK=(-m "slow or not slow")
fi
python -m repro.core.analysis.lint src/repro
# ${MARK[@]+...} keeps set -u happy on bash < 4.4 when MARK is empty
python -m pytest -x -q --durations=10 \
    ${MARK[@]+"${MARK[@]}"} "$@"
python -m repro.core.analysis.verify
python -m benchmarks.serving_benchmarks --smoke --suite all
if [ "$DIFFERENTIAL" = "1" ]; then
    python -m pytest -x -q tests/test_differential.py
fi
if [ "$SCHEDULER" = "1" ]; then
    python -m pytest -x -q tests/test_scheduler.py
    python -m benchmarks.serving_benchmarks --smoke --suite multitenant
fi
if [ "$PROPERTIES" = "1" ]; then
    python -m pytest -x -q -m "properties and not slow" \
        tests/test_properties.py tests/test_seg_kernels.py
fi
if [ "$KERNELS" = "1" ]; then
    REPRO_KERNEL_INTERPRET=1 python -m pytest -x -q \
        tests/test_seg_kernels.py tests/test_kernels.py
    python -m benchmarks.serving_benchmarks --smoke --suite kernels
fi
if [ "$OBS" = "1" ]; then
    python -m pytest -x -q tests/test_obs.py
    python -m benchmarks.serving_benchmarks --smoke --suite obs
fi
if [ "$CAPACITY" = "1" ]; then
    python -m pytest -x -q tests/test_capacity.py
    python -m benchmarks.serving_benchmarks --smoke --suite capacity
fi
if [ "$PERSIST" = "1" ]; then
    python -m pytest -x -q tests/test_persist.py
    python -m benchmarks.serving_benchmarks --smoke --suite restart
fi
