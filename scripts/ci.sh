#!/usr/bin/env bash
# Tier-1 CI loop: the ROADMAP verify command plus timing report.
#
#   scripts/ci.sh              default loop (slow-marked smokes skipped)
#   FULL=1 scripts/ci.sh       include slow-marked arch smoke tests
#   scripts/ci.sh tests/...    any extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
MARK=()
if [ "${FULL:-0}" = "1" ]; then
    MARK=(-m "slow or not slow")
fi
# ${MARK[@]+...} keeps set -u happy on bash < 4.4 when MARK is empty
exec python -m pytest -x -q --durations=10 \
    ${MARK[@]+"${MARK[@]}"} "$@"
